//! Corruption-injection acceptance suite of the `synth::verify`
//! invariant checker (DESIGN.md §7).
//!
//! Two halves:
//!
//! * **Clean pins** — the template/arena states the rest of the test
//!   matrix exercises (`ga_determinism.rs` mutation chains over the
//!   tiny MLP, `measured_objectives.rs` template instantiation) must
//!   verify with zero violations, and the evaluator's `--verify
//!   every-gen` hook must count checks without counting violations.
//! * **Seeded breaks** — each invariant class is deliberately broken
//!   (cycle, dangling CSR edge, duplicate hash key, orphaned param
//!   bit, stale arrival, census drift) through the `#[doc(hidden)]`
//!   corruption hooks or direct mutation of public fields, and the
//!   matching check — and *only* it — must fire, naming the corrupted
//!   nodes.
//!
//! The seeds are chosen so each break is invisible to every other
//! check: gate-list breaks use a small *group-free* template (so the
//! cone-frontier check is vacuous) and are seeded either before
//! `Template::new` (cycle — the CSR is then built consistently over
//! the broken gates) or on operand-free nodes (orphaned param — the
//! fanout lists don't move); arena breaks use hooks that keep the
//! arrival/census bookkeeping of everything *else* intact.

use printed_mlp::accum::GenomeMap;
use printed_mlp::config::builtin;
use printed_mlp::datasets;
use printed_mlp::ga::Evaluator;
use printed_mlp::model::float_mlp::TrainOpts;
use printed_mlp::model::{FloatMlp, QuantMlp};
use printed_mlp::netlist::mlp::{build_mlp_template, ArgmaxMode};
use printed_mlp::netlist::{Gate, Netlist, NodeId, Template};
use printed_mlp::runtime::evaluator::CircuitEvaluator;
use printed_mlp::synth::incremental::IncrementalSynth;
use printed_mlp::synth::verify::{verify_arena, verify_template, VerifyMode, Violation};
use printed_mlp::util::telemetry::{self, Work};
use printed_mlp::util::Rng;

fn tiny_setup() -> (QuantMlp, printed_mlp::datasets::QuantDataset, f64) {
    let cfg = builtin::tiny();
    let (split, qtrain, _) = datasets::load(&cfg.dataset);
    let mut mlp = FloatMlp::init(cfg.topology, 1);
    mlp.train(&split.train, &TrainOpts { epochs: 20, ..Default::default() });
    let qmlp = QuantMlp::from_float(&mlp, &qtrain);
    let base = qmlp.accuracy(&qtrain, None);
    (qmlp, qtrain, base)
}

/// Node ids of the [`flat_netlist`] fixture, in construction order.
struct Flat {
    a: NodeId,
    b: NodeId,
    p0: NodeId,
    t0: NodeId,
    y: NodeId,
}

/// A tiny *group-free* template netlist — two inputs, two params,
/// three cells: `y = (a & p0) | (b ^ p1)`. With no registered cone
/// groups the cone-frontier check is vacuously clean, so a seeded
/// gate-list break here can implicate exactly one check.
fn flat_netlist() -> (Netlist, Flat) {
    let mut nl = Netlist::new();
    let a = nl.input();
    let b = nl.input();
    let p0 = nl.param(0);
    let p1 = nl.param(1);
    let t0 = nl.and(a, p0);
    let t1 = nl.xor(b, p1);
    let y = nl.or(t0, t1);
    nl.output("y", vec![y]);
    (nl, Flat { a, b, p0, t0, y })
}

/// The distinct check ids present in a violation list, sorted.
fn checks_fired(vs: &[Violation]) -> Vec<&'static str> {
    let mut c: Vec<&'static str> = vs.iter().map(|v| v.check).collect();
    c.sort_unstable();
    c.dedup();
    c
}

/// The tiny MLP template plus a live incremental arena advanced through
/// a deterministic mutation chain — the arena states the determinism
/// and measured-objective suites evaluate, with every intermediate
/// state verified clean along the way.
fn mlp_arena(states: usize, share: bool) -> (IncrementalSynth, usize) {
    let (qmlp, _, _) = tiny_setup();
    let map = GenomeMap::new(&qmlp);
    let tpl = build_mlp_template(&qmlp, &ArgmaxMode::Exact);
    assert!(verify_template(&tpl, Some(map.len())).is_empty());
    let mut synth = IncrementalSynth::new(tpl);
    synth.set_share_cones(share);
    let mut rng = Rng::new(7);
    let mut g = map.exact_genome();
    for s in 0..states {
        if s > 0 {
            for _ in 0..3 {
                g.flip(rng.below(map.len()));
            }
        }
        synth.set_params(&g);
        let vs = verify_arena(&synth, Some(map.len()));
        assert!(vs.is_empty(), "state {s} (share={share}): {vs:?}");
    }
    (synth, map.len())
}

// ---------------------------------------------------------------- clean pins

#[test]
fn clean_template_and_arena_states_verify_zero_violations() {
    // The hand-built fixture, before and after instantiation plumbing.
    let (nl, _) = flat_netlist();
    let tpl = Template::new(nl, 2);
    assert!(verify_template(&tpl, Some(2)).is_empty());

    // An unready arena runs only the template checks — still clean.
    let synth = IncrementalSynth::new(tpl);
    assert!(verify_arena(&synth, Some(2)).is_empty());

    // The real tiny MLP template + mutation-chain arena states used by
    // ga_determinism.rs / measured_objectives.rs, with and without
    // cross-chromosome cone sharing. (mlp_arena verifies every state.)
    let _ = mlp_arena(5, true);
    let _ = mlp_arena(5, false);
}

#[test]
fn every_gen_evaluator_counts_checks_but_no_violations() {
    // The pipeline hook end-to-end: evaluating the determinism suite's
    // genome chain under --verify every-gen must run checks on every
    // chromosome and count zero violations; --verify off (the default)
    // must not run any.
    let (qmlp, qtrain, base) = tiny_setup();
    let ev = CircuitEvaluator::new(&qmlp, &qtrain, base).with_verify(VerifyMode::EveryGen);
    assert_eq!(ev.verify(), VerifyMode::EveryGen);
    let mut rng = Rng::new(11);
    let mut genomes = vec![ev.map.exact_genome()];
    for _ in 0..5 {
        let mut g = genomes.last().unwrap().clone();
        for _ in 0..3 {
            g.flip(rng.below(ev.map.len()));
        }
        genomes.push(g);
    }

    let before = telemetry::thread_block();
    let objs = ev.evaluate(&genomes);
    let d = telemetry::thread_block().delta(&before);
    assert_eq!(objs.len(), genomes.len());
    assert!(d.work[Work::VerifyChecksRun as usize] > 0, "every-gen must run checks");
    assert_eq!(d.work[Work::VerifyViolations as usize], 0, "clean states, no violations");

    let off = CircuitEvaluator::new(&qmlp, &qtrain, base);
    assert_eq!(off.verify(), VerifyMode::Off);
    let before = telemetry::thread_block();
    let _ = off.evaluate(&genomes);
    let d = telemetry::thread_block().delta(&before);
    assert_eq!(d.work[Work::VerifyChecksRun as usize], 0, "--verify off is zero-cost");
}

// ------------------------------------------------------------- seeded breaks

#[test]
fn seeded_cycle_fires_only_the_acyclic_check() {
    // Rewrite the AND cell into a self-loop *before* Template::new, so
    // the CSR is built consistently over the broken gate list and only
    // topological order is violated.
    let (mut nl, ids) = flat_netlist();
    nl.gates[ids.t0 as usize] = Gate::Not(ids.t0);
    let tpl = Template::new(nl, 2);
    let vs = verify_template(&tpl, Some(2));
    assert_eq!(checks_fired(&vs), ["acyclic"], "{vs:?}");
    assert_eq!(vs.len(), 1);
    assert!(vs[0].nodes.contains(&ids.t0), "diagnostic must name the looping node: {}", vs[0]);
}

#[test]
fn dangling_csr_edge_fires_only_the_csr_fanout_check() {
    // Redirect the first fanout slot — input `a`'s one consumer edge,
    // which points at the AND cell — to an unrelated node. The gate
    // list itself stays intact, so only the adjacency recompute trips.
    let (nl, ids) = flat_netlist();
    let mut tpl = Template::new(nl, 2);
    let old = tpl.corrupt_fanout_slot(0, ids.b);
    assert_eq!(old, ids.t0, "slot 0 is a's edge to the AND cell");
    let vs = verify_template(&tpl, Some(2));
    assert_eq!(checks_fired(&vs), ["csr-fanout"], "{vs:?}");
    assert_eq!(vs.len(), 1, "one source node's list drifted");
    assert!(
        vs[0].nodes.contains(&ids.a) && vs[0].nodes.contains(&ids.t0),
        "diagnostic must name the source and the lost consumer: {}",
        vs[0]
    );
}

#[test]
fn duplicate_hash_key_fires_only_the_struct_hash_check() {
    // Push an unregistered structural copy of a live cell into the
    // arena. Its arrival is bookkept correctly and it is unreachable
    // from the outputs, so arrival/census stay clean — but two nodes
    // now share one structural key and the table count is short by one.
    let (mut synth, glen) = mlp_arena(2, true);
    let id = synth
        .arena()
        .gates
        .iter()
        .position(|g| g.is_cell())
        .expect("tiny MLP arena has cells") as NodeId;
    let dup = synth.corrupt_duplicate_node(id);
    let vs = verify_arena(&synth, Some(glen));
    assert_eq!(checks_fired(&vs), ["struct-hash"], "{vs:?}");
    assert!(
        vs.iter().any(|v| v.nodes.contains(&dup) && v.nodes.contains(&id)),
        "diagnostic must name both nodes sharing the key: {vs:?}"
    );
    assert!(
        vs.iter().any(|v| v.detail.contains("hash table holds")),
        "table-count cross-check must also trip: {vs:?}"
    );
}

#[test]
fn orphaned_param_bit_fires_only_the_param_bijection_check() {
    // Overwrite a registered Param site with a Const *after* the CSR is
    // built. Both gates are operand-free, so adjacency and topological
    // order are untouched — but genome bit 0 now binds nothing.
    let (nl, _) = flat_netlist();
    let mut tpl = Template::new(nl, 2);
    let pid = tpl.param_nodes[0];
    tpl.nl.gates[pid as usize] = Gate::Const(false);
    let vs = verify_template(&tpl, Some(2));
    assert_eq!(checks_fired(&vs), ["param-bijection"], "{vs:?}");
    assert!(
        vs.iter().any(|v| v.nodes.contains(&pid)),
        "diagnostic must name the orphaned site: {vs:?}"
    );
    assert!(
        vs.iter().any(|v| v.detail.contains("binds nothing")),
        "the bit-binds-nothing diagnosis must be spelled out: {vs:?}"
    );
}

#[test]
fn stale_arrival_fires_only_the_arrival_check() {
    // Zero out one cell's arrival time. Lowering can't break downstream
    // monotonicity, so exactly the recompute-mismatch family trips —
    // at the stale node itself (and possibly its direct consumers,
    // whose recomputed times read the corrupted operand).
    let (mut synth, glen) = mlp_arena(1, true);
    let id = synth
        .arena()
        .gates
        .iter()
        .position(|g| g.is_cell())
        .expect("tiny MLP arena has cells") as NodeId;
    let old = synth.corrupt_arrival(id, 0.0);
    assert!(old > 0.0, "a cell's true arrival includes its own delay");
    let vs = verify_arena(&synth, Some(glen));
    assert_eq!(checks_fired(&vs), ["arrival"], "{vs:?}");
    assert!(
        vs.iter().any(|v| v.nodes.contains(&id)),
        "diagnostic must name the stale node: {vs:?}"
    );
}

#[test]
fn census_drift_fires_only_the_census_check() {
    // Drop one cell from the live list without touching the histogram
    // or the arena. The reachability walk still finds it (set diff),
    // and the histogram total no longer matches the list length.
    let (mut synth, glen) = mlp_arena(1, true);
    let dropped = synth.corrupt_census_drop_live().expect("live cells present");
    let vs = verify_arena(&synth, Some(glen));
    assert_eq!(checks_fired(&vs), ["census"], "{vs:?}");
    assert_eq!(vs.len(), 2, "set diff + total mismatch");
    assert!(
        vs[0].nodes.contains(&dropped),
        "diagnostic must name the dropped cell: {}",
        vs[0]
    );
}

#[test]
fn violation_display_is_actionable() {
    // The rendered diagnostic carries the check id, the node ids and
    // the explanation — what `pmlp lint` and the boundary checkpoints
    // print via telemetry.
    let (mut nl, ids) = flat_netlist();
    nl.gates[ids.y as usize] = Gate::Or(ids.y, ids.p0);
    let tpl = Template::new(nl, 2);
    let vs = verify_template(&tpl, Some(2));
    assert_eq!(checks_fired(&vs), ["acyclic"]);
    let msg = vs[0].to_string();
    assert!(msg.starts_with("[acyclic]"), "{msg}");
    assert!(msg.contains(&format!("{}", ids.y)), "{msg}");
}
