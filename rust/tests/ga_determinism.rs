//! Determinism of the population-parallel GA evaluation fan-out: with
//! the same seed, `--jobs 1` and `--jobs 8` must produce bit-identical
//! `GaResult`s — fronts (genomes + objectives), final population,
//! convergence history, and the per-generation log stream — on every
//! backend. This is the property that makes `--jobs` a pure throughput
//! knob: parallel runs are exactly reproducible and cross-comparable
//! with serial ones.
//!
//! CI runs the whole test suite twice (`PMLP_JOBS=1` and `PMLP_JOBS=2`),
//! so the `jobs = 0` (auto) paths in the pipeline integration tests also
//! execute under both serial and genuinely concurrent widths.

use printed_mlp::config::builtin;
use printed_mlp::datasets;
use printed_mlp::egfet::CostObjective;
use printed_mlp::ga::{Evaluator, GaResult, Nsga2};
use printed_mlp::model::float_mlp::TrainOpts;
use printed_mlp::model::{FloatMlp, QuantMlp};
use printed_mlp::runtime::evaluator::{CircuitEvaluator, NativeEvaluator};
use printed_mlp::runtime::{PjrtEvaluator, Runtime};
use printed_mlp::synth::SynthMode;
use printed_mlp::util::telemetry;
use printed_mlp::util::BitVec;

fn tiny_setup() -> (QuantMlp, printed_mlp::datasets::QuantDataset, f64) {
    let cfg = builtin::tiny();
    let (split, qtrain, _) = datasets::load(&cfg.dataset);
    let mut mlp = FloatMlp::init(cfg.topology, 1);
    mlp.train(&split.train, &TrainOpts { epochs: 20, ..Default::default() });
    let qmlp = QuantMlp::from_float(&mlp, &qtrain);
    let base = qmlp.accuracy(&qtrain, None);
    (qmlp, qtrain, base)
}

fn ga_spec() -> printed_mlp::config::GaSpec {
    let mut spec = builtin::tiny().ga;
    spec.population = 16;
    spec.generations = 3;
    spec
}

/// Everything observable about a run, in comparable form: the final
/// population and front (genome bits + objectives), the history, and
/// the log stream the generation callback saw. Generic over the GA's
/// objective arity, like the core it fingerprints.
type RunFingerprint<const M: usize> = (
    Vec<(Vec<bool>, [f64; M])>,
    Vec<(Vec<bool>, [f64; M])>,
    Vec<(f64, f64)>,
    Vec<(usize, Vec<(f64, f64)>)>,
);

fn fingerprint<const M: usize>(
    result: &GaResult<M>,
    log: Vec<(usize, Vec<(f64, f64)>)>,
) -> RunFingerprint<M> {
    let pack = |inds: &[printed_mlp::ga::Individual<M>]| -> Vec<(Vec<bool>, [f64; M])> {
        inds.iter().map(|i| (i.genome.iter().collect(), i.objs)).collect()
    };
    (pack(&result.population), pack(&result.front), result.history.clone(), log)
}

/// Run the GA at a given worker width and fingerprint the outcome.
fn run_at<const M: usize>(
    ev: &dyn Evaluator<M>,
    genome_len: usize,
    seeds: &[BitVec],
    jobs: usize,
) -> RunFingerprint<M> {
    let mut log = Vec::new();
    let result = Nsga2::new(ga_spec(), genome_len, ev)
        .with_seeds(seeds.to_vec())
        .with_jobs(jobs)
        .run(|generation, snap| log.push((generation, snap.history.clone())));
    fingerprint(&result, log)
}

#[test]
fn native_backend_jobs_1_vs_8_bit_identical() {
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let ev = NativeEvaluator::new(&qmlp, &qtrain, base);
    let serial = run_at::<2>(&ev, glen, &[], 1);
    let parallel = run_at::<2>(&ev, glen, &[], 8);
    assert_eq!(serial, parallel);
}

#[test]
fn circuit_incremental_jobs_1_vs_8_bit_identical() {
    // Fresh evaluator per width: each has its own memo and worker-arena
    // pool, so agreement cannot come from shared caches.
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let serial_ev = CircuitEvaluator::new(&qmlp, &qtrain, base);
    let par_ev = CircuitEvaluator::new(&qmlp, &qtrain, base);
    let serial = run_at::<2>(&serial_ev, glen, &[], 1);
    let parallel = run_at::<2>(&par_ev, glen, &[], 8);
    assert_eq!(serial, parallel);
}

#[test]
fn circuit_full_jobs_1_vs_8_bit_identical() {
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let serial_ev = CircuitEvaluator::new(&qmlp, &qtrain, base).with_mode(SynthMode::Full);
    let par_ev = CircuitEvaluator::new(&qmlp, &qtrain, base).with_mode(SynthMode::Full);
    let serial = run_at::<2>(&serial_ev, glen, &[], 1);
    let parallel = run_at::<2>(&par_ev, glen, &[], 8);
    assert_eq!(serial, parallel);
}

#[test]
fn circuit_power_objective_jobs_1_vs_8_bit_identical() {
    // Measured-hardware objective (`--objective power`): the survivor
    // census + toggle-activity state lives in per-worker arena/cache
    // leases, so any evaluation width must still produce a bit-identical
    // GaResult. Fresh evaluators per width (own memo + arena pool).
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let serial_ev =
        CircuitEvaluator::new(&qmlp, &qtrain, base).with_objective(CostObjective::Power);
    let par_ev =
        CircuitEvaluator::new(&qmlp, &qtrain, base).with_objective(CostObjective::Power);
    let serial = run_at::<2>(&serial_ev, glen, &[], 1);
    let parallel = run_at::<2>(&par_ev, glen, &[], 8);
    assert_eq!(serial, parallel);
}

#[test]
fn circuit_power_objective_modes_agree_at_width_8() {
    // Full-mode measured scoring synthesizes from scratch through the
    // same template flow, so both synthesis strategies walk the same GA
    // trajectory even on the measured cost axis — across widths.
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let incr_ev =
        CircuitEvaluator::new(&qmlp, &qtrain, base).with_objective(CostObjective::Power);
    let full_ev = CircuitEvaluator::new(&qmlp, &qtrain, base)
        .with_mode(SynthMode::Full)
        .with_objective(CostObjective::Power);
    let a = run_at::<2>(&incr_ev, glen, &[], 8);
    let b = run_at::<2>(&full_ev, glen, &[], 1);
    assert_eq!(a, b);
}

#[test]
fn circuit_joint_objective_jobs_1_vs_8_bit_identical() {
    // The three-objective `--objective area+power` front: the joint
    // census + toggle state rides the same per-worker lease as the
    // single measured objectives, so jobs 1 and jobs 8 must produce a
    // bit-identical 3-D GaResult. Fresh evaluators per width.
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let serial_ev = CircuitEvaluator::new_joint(&qmlp, &qtrain, base);
    let par_ev = CircuitEvaluator::new_joint(&qmlp, &qtrain, base);
    let serial = run_at::<3>(&serial_ev, glen, &[], 1);
    let parallel = run_at::<3>(&par_ev, glen, &[], 8);
    assert_eq!(serial, parallel);
}

#[test]
fn circuit_joint_objective_modes_agree_at_width_8() {
    // Full-mode joint scoring synthesizes from scratch through the same
    // template flow and fills both cost axes from the same roll-up, so
    // both synthesis strategies walk the same 3-D GA trajectory.
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let incr_ev = CircuitEvaluator::new_joint(&qmlp, &qtrain, base);
    let full_ev =
        CircuitEvaluator::new_joint(&qmlp, &qtrain, base).with_mode(SynthMode::Full);
    let a = run_at::<3>(&incr_ev, glen, &[], 8);
    let b = run_at::<3>(&full_ev, glen, &[], 1);
    assert_eq!(a, b);
}

#[test]
fn circuit_joint_delay_jobs_1_vs_8_bit_identical() {
    // The four-objective `--objective area+power+delay` front: the
    // delay axis is read off each worker's incremental arena arrival
    // table (settled once per emitted node, shared-cone memo hits
    // included — sharing defaults on), so jobs 1 and jobs 8 must
    // produce a bit-identical 4-D GaResult. Fresh evaluators per width.
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let serial_ev = CircuitEvaluator::new_joint_delay(&qmlp, &qtrain, base);
    let par_ev = CircuitEvaluator::new_joint_delay(&qmlp, &qtrain, base);
    assert!(serial_ev.cone_sharing(), "sharing must default on");
    let serial = run_at::<4>(&serial_ev, glen, &[], 1);
    let parallel = run_at::<4>(&par_ev, glen, &[], 8);
    assert_eq!(serial, parallel);
}

#[test]
fn circuit_joint_delay_modes_agree_at_width_8() {
    // Full-mode joint-delay scoring times the from-scratch survivor
    // through `egfet`, the incremental mode folds the arena's arrival
    // table — the tentpole's bit-exactness contract says both walk the
    // same 4-D GA trajectory at any width.
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let incr_ev = CircuitEvaluator::new_joint_delay(&qmlp, &qtrain, base);
    let full_ev =
        CircuitEvaluator::new_joint_delay(&qmlp, &qtrain, base).with_mode(SynthMode::Full);
    let a = run_at::<4>(&incr_ev, glen, &[], 8);
    let b = run_at::<4>(&full_ev, glen, &[], 1);
    assert_eq!(a, b);
}

#[test]
fn circuit_joint_delay_lane_widths_and_sharing_bit_identical() {
    // The 4-D run through the full throughput-knob matrix: lane width ×
    // cone sharing × worker width must all reproduce the same GaResult
    // bit-for-bit — the arrival table lives in the synthesis arena, not
    // the wave engine, so no knob may perturb the delay axis. Fresh
    // evaluator per cell.
    use printed_mlp::sim::wave::LaneWidth;
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let reference = {
        let ev = CircuitEvaluator::new_joint_delay(&qmlp, &qtrain, base)
            .with_lane_width(LaneWidth::W64)
            .with_cone_sharing(false);
        run_at::<4>(&ev, glen, &[], 1)
    };
    for width in [LaneWidth::W64, LaneWidth::W256] {
        for share in [false, true] {
            for jobs in [1usize, 8] {
                let ev = CircuitEvaluator::new_joint_delay(&qmlp, &qtrain, base)
                    .with_lane_width(width)
                    .with_cone_sharing(share);
                assert_eq!(
                    run_at::<4>(&ev, glen, &[], jobs),
                    reference,
                    "width={width:?} share={share} jobs={jobs}"
                );
            }
        }
    }
}

#[test]
fn circuit_shared_cones_on_vs_off_jobs_1_and_8_bit_identical() {
    // The generation-scoped shared-cone memo is exact: a memo hit
    // replays byte-for-byte the reprs a re-synthesis would derive, so
    // enabling it — at any worker width — must leave the GaResult
    // bit-identical to the unshared engine. Fresh evaluator per cell of
    // the (sharing, jobs) matrix so agreement cannot come from shared
    // caches.
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let reference = {
        let ev = CircuitEvaluator::new(&qmlp, &qtrain, base).with_cone_sharing(false);
        run_at::<2>(&ev, glen, &[], 1)
    };
    for share in [false, true] {
        for jobs in [1usize, 8] {
            let ev = CircuitEvaluator::new(&qmlp, &qtrain, base).with_cone_sharing(share);
            assert_eq!(
                run_at::<2>(&ev, glen, &[], jobs),
                reference,
                "share={share} jobs={jobs}"
            );
        }
    }
}

#[test]
fn circuit_lane_widths_64_vs_256_bit_identical() {
    // `--lane-width` is a pure throughput knob: the 64-lane legacy
    // engine and the 256-lane block engine must walk the same GA
    // trajectory bit-for-bit at any worker width.
    use printed_mlp::sim::wave::LaneWidth;
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let reference = {
        let ev = CircuitEvaluator::new(&qmlp, &qtrain, base).with_lane_width(LaneWidth::W64);
        run_at::<2>(&ev, glen, &[], 1)
    };
    for width in [LaneWidth::W64, LaneWidth::W256] {
        for jobs in [1usize, 8] {
            let ev = CircuitEvaluator::new(&qmlp, &qtrain, base).with_lane_width(width);
            assert_eq!(
                run_at::<2>(&ev, glen, &[], jobs),
                reference,
                "width={width:?} jobs={jobs}"
            );
        }
    }
}

#[test]
fn backends_agree_with_each_other_at_any_width() {
    // Cross-backend: the circuit backend measures accuracy on netlists
    // verified equivalent to the integer model, so native @1 job and
    // circuit @8 jobs must still walk the same GA trajectory.
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let native = NativeEvaluator::new(&qmlp, &qtrain, base);
    let circuit = CircuitEvaluator::new(&qmlp, &qtrain, base);
    let a = run_at::<2>(&native, glen, &[], 1);
    let b = run_at::<2>(&circuit, glen, &[], 8);
    assert_eq!(a, b);
}

/// Telemetry counters this thread accumulated over one GA run at the
/// given width. Worker blocks merge into the calling thread's block at
/// the `par_map_with` writeback, so the before/after delta captures
/// exactly this run's counts — isolated from concurrently running tests
/// (each test runs on its own thread with its own block).
fn counters_during<const M: usize>(
    ev: &dyn Evaluator<M>,
    genome_len: usize,
    jobs: usize,
) -> Vec<(&'static str, u64)> {
    let before = telemetry::thread_block();
    let _ = run_at::<M>(ev, genome_len, &[], jobs);
    telemetry::thread_block().delta(&before).counters_named()
}

fn counter_of(counters: &[(&'static str, u64)], name: &str) -> u64 {
    counters.iter().find(|(n, _)| *n == name).unwrap_or_else(|| panic!("no counter {name}")).1
}

#[test]
fn native_counters_jobs_1_vs_8_bit_identical() {
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let ev = NativeEvaluator::new(&qmlp, &qtrain, base);
    let serial = counters_during::<2>(&ev, glen, 1);
    let parallel = counters_during::<2>(&ev, glen, 8);
    assert_eq!(serial, parallel);
    // 1 initial evaluation + one per generation.
    assert_eq!(counter_of(&serial, "ga.generations"), 3);
    assert_eq!(counter_of(&serial, "ga.evaluate_calls"), 4);
    assert!(counter_of(&serial, "ga.genomes_in") >= 4 * 16);
}

#[test]
fn circuit_incremental_counters_jobs_1_vs_8_bit_identical() {
    // Fresh evaluator per width (own memo + arena pool), like the
    // GaResult tests above: identical counts cannot come from shared
    // caches. Memo hit/miss totals are width-invariant because batch
    // dedup probes each unique genome once and inserts land at batch
    // boundaries — the heart of the determinism contract.
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let serial_ev = CircuitEvaluator::new(&qmlp, &qtrain, base);
    let par_ev = CircuitEvaluator::new(&qmlp, &qtrain, base);
    let serial = counters_during::<2>(&serial_ev, glen, 1);
    let parallel = counters_during::<2>(&par_ev, glen, 8);
    assert_eq!(serial, parallel);
    assert!(counter_of(&serial, "evaluator.memo_misses") > 0);
    assert!(counter_of(&serial, "synth.set_params") > 0);
    assert!(counter_of(&serial, "wave.vectors_classified") > 0);
    assert!(counter_of(&serial, "sharded.gets") > 0);
    // Every unique genome is probed exactly once per batch.
    assert_eq!(
        counter_of(&serial, "evaluator.memo_hits") + counter_of(&serial, "evaluator.memo_misses"),
        counter_of(&serial, "ga.genomes_unique")
    );
}

#[test]
fn circuit_full_counters_jobs_1_vs_8_bit_identical() {
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let serial_ev = CircuitEvaluator::new(&qmlp, &qtrain, base).with_mode(SynthMode::Full);
    let par_ev = CircuitEvaluator::new(&qmlp, &qtrain, base).with_mode(SynthMode::Full);
    let serial = counters_during::<2>(&serial_ev, glen, 1);
    let parallel = counters_during::<2>(&par_ev, glen, 8);
    assert_eq!(serial, parallel);
    assert!(counter_of(&serial, "evaluator.memo_misses") > 0);
    assert!(counter_of(&serial, "wave.classify_calls") > 0);
}

#[test]
fn shared_cone_work_consistent_with_unique_genomes_at_jobs_1() {
    // At jobs=1 the shared-cone work stats are deterministic and must
    // book-keep against the genome stream: every evaluator-memo miss is
    // one synthesis pass, every cone pass probes between 1 and
    // `cone_groups.len()` groups (GA deltas are param flips, and every
    // param site lives inside a registered group), and every probe is
    // either a hit or a miss.
    use printed_mlp::netlist::mlp::{build_mlp_template, ArgmaxMode};
    use printed_mlp::util::telemetry::Work;
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let n_groups = build_mlp_template(&qmlp, &ArgmaxMode::Exact).cone_groups.len() as u64;
    assert!(n_groups > 0, "MLP template must register cone groups");
    let ev = CircuitEvaluator::new(&qmlp, &qtrain, base);
    assert!(ev.cone_sharing(), "sharing must default on");
    let before = telemetry::thread_block();
    let _ = run_at::<2>(&ev, glen, &[], 1);
    let d = telemetry::thread_block().delta(&before);
    let unique = counter_of(&d.counters_named(), "ga.genomes_unique");
    let memo_misses = counter_of(&d.counters_named(), "evaluator.memo_misses");
    let hits = d.work[Work::SynthSharedConeHits as usize];
    let misses = d.work[Work::SynthSharedConeMisses as usize];
    let cone_passes = d.work[Work::SynthConePasses as usize];
    let full_passes = d.work[Work::SynthFullPasses as usize];
    let probes = hits + misses;
    assert_eq!(
        cone_passes + full_passes,
        memo_misses,
        "every evaluator-memo miss is exactly one synthesis pass"
    );
    assert!(probes >= cone_passes, "every cone pass probes >=1 dirty group");
    assert!(
        probes <= cone_passes * n_groups,
        "a cone pass probes at most every group: {probes} > {cone_passes} * {n_groups}"
    );
    assert!(probes <= unique * n_groups);
    assert!(
        d.work[Work::WaveBlockPasses as usize] >= 1,
        "the default 256-lane engine must count block passes"
    );
}

#[test]
fn pjrt_backend_jobs_1_vs_8_bit_identical() {
    // Third backend of the determinism matrix — runs only where the AOT
    // artifacts (and the `xla` feature) are present, like the rest of
    // the PJRT integration suite.
    let rt = match Runtime::new(&Runtime::default_dir()) {
        Ok(rt) => rt,
        Err(_) => {
            eprintln!("skipping: PJRT runtime unavailable (artifacts or `xla` feature missing)");
            return;
        }
    };
    if !rt.manifest.entries.contains_key("tiny") {
        eprintln!("skipping: no 'tiny' artifact");
        return;
    }
    let (qmlp, qtrain, base) = tiny_setup();
    let glen = printed_mlp::accum::GenomeMap::new(&qmlp).len();
    let ev = PjrtEvaluator::new(&rt, "tiny", &qmlp, &qtrain, base).expect("pjrt evaluator");
    let serial = run_at::<2>(&ev, glen, &[], 1);
    let parallel = run_at::<2>(&ev, glen, &[], 8);
    assert_eq!(serial, parallel);
}
