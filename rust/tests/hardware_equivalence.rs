//! Integration: the hardware equivalence chain on a trained model —
//! `gate-level netlist simulation == Rust integer model`, exact and
//! masked, plus synthesized-circuit monotonicity (DESIGN.md §2).

use printed_mlp::accum::GenomeMap;
use printed_mlp::argmax::{build_plan, ArgmaxSearchOpts};
use printed_mlp::config::builtin;
use printed_mlp::datasets;
use printed_mlp::model::float_mlp::TrainOpts;
use printed_mlp::model::{FloatMlp, QuantMlp};
use printed_mlp::netlist::mlp::{build_mlp_circuit, ArgmaxMode, MlpCircuitOpts};
use printed_mlp::sim::{bus_to_u64, eval, u64_to_bits};
use printed_mlp::synth::optimize;
use printed_mlp::util::Rng;

fn trained() -> (QuantMlp, datasets::QuantDataset) {
    let cfg = builtin::tiny();
    let (split, qtrain, _) = datasets::load(&cfg.dataset);
    let mut mlp = FloatMlp::init(cfg.topology, 3);
    mlp.train(&split.train, &TrainOpts { epochs: 30, ..Default::default() });
    mlp.train(
        &split.train,
        &TrainOpts { epochs: 15, qat_po2: true, lr: 0.008, ..Default::default() },
    );
    (QuantMlp::from_float(&mlp, &qtrain), qtrain)
}

fn encode(x: &[u32]) -> Vec<bool> {
    let mut bits = Vec::new();
    for &v in x {
        bits.extend(u64_to_bits(v as u64, 4));
    }
    bits
}

#[test]
fn full_approximate_circuit_equals_model_predictions() {
    let (qmlp, qtrain) = trained();
    let map = GenomeMap::new(&qmlp);
    let mut rng = Rng::new(17);
    let genome = map.random_genome(&mut rng, 0.75);
    let masks = map.to_masks(&genome);

    // Approximate argmax plan on the masked model.
    let preacts = qmlp.output_preacts(&qtrain, Some(&masks));
    let plan = build_plan(
        &preacts,
        &qtrain.y,
        qmlp.output_width(),
        &ArgmaxSearchOpts::default(),
    );

    // Full holistic circuit, synthesized.
    let nl = build_mlp_circuit(
        &qmlp,
        &MlpCircuitOpts {
            masks: Some(masks.clone()),
            argmax: ArgmaxMode::Plan(plan.clone()),
        },
    );
    let (opt, stats) = optimize(&nl);
    assert!(stats.cells_out <= stats.cells_in);

    // Gate-level simulation == model + plan, sample by sample.
    for (row, z) in qtrain.x.iter().zip(&preacts).take(60) {
        let expect = plan.predict(z);
        let out = eval(&opt, &encode(row));
        assert_eq!(bus_to_u64(&out["class"]) as usize, expect);
    }
}

#[test]
fn synthesis_never_changes_function() {
    let (qmlp, qtrain) = trained();
    let nl = build_mlp_circuit(&qmlp, &MlpCircuitOpts::default());
    let (opt, _) = optimize(&nl);
    for row in qtrain.x.iter().take(60) {
        let a = eval(&nl, &encode(row));
        let b = eval(&opt, &encode(row));
        assert_eq!(a["class"], b["class"]);
    }
}

#[test]
fn deeper_masking_monotonically_shrinks_synthesized_area() {
    let (qmlp, _) = trained();
    let map = GenomeMap::new(&qmlp);
    let mut last = usize::MAX;
    for keep in [1.0, 0.7, 0.4, 0.1] {
        let mut rng = Rng::new(23);
        let genome = map.random_genome(&mut rng, keep);
        let masks = map.to_masks(&genome);
        let nl = build_mlp_circuit(
            &qmlp,
            &MlpCircuitOpts { masks: Some(masks), argmax: ArgmaxMode::Exact },
        );
        let (opt, _) = optimize(&nl);
        let cells = opt.cell_count();
        assert!(
            cells <= last,
            "keep={keep}: {cells} cells > previous {last}"
        );
        last = cells;
    }
}

#[test]
fn egfet_reports_scale_with_circuit_size() {
    use printed_mlp::egfet::{analyze, Library};
    let (qmlp, _) = trained();
    let nl_exact = build_mlp_circuit(&qmlp, &MlpCircuitOpts::default());
    let (opt_exact, _) = optimize(&nl_exact);
    let map = GenomeMap::new(&qmlp);
    let mut rng = Rng::new(29);
    let masks = map.to_masks(&map.random_genome(&mut rng, 0.3));
    let nl_small = build_mlp_circuit(
        &qmlp,
        &MlpCircuitOpts { masks: Some(masks), argmax: ArgmaxMode::Exact },
    );
    let (opt_small, _) = optimize(&nl_small);
    let lib = Library::egfet_1v();
    let big = analyze(&opt_exact, &lib, 200.0, 0.25);
    let small = analyze(&opt_small, &lib, 200.0, 0.25);
    assert!(small.area_cm2 < big.area_cm2);
    assert!(small.power_mw < big.power_mw);
    assert!(small.delay_ms <= big.delay_ms + 1e-9);
}
