//! Integration: the hardware equivalence chain on a trained model —
//! `gate-level netlist simulation == Rust integer model`, exact and
//! masked, plus synthesized-circuit monotonicity (DESIGN.md §2).
//!
//! The batch sweeps run on the bit-parallel wave engine; two tests
//! additionally pin it to the scalar simulator lane-by-lane on a real
//! synthesized MLP circuit — once through the legacy 64-lane `u64` API
//! and once through the production 256-lane `[u64; 4]` block API.

use printed_mlp::accum::GenomeMap;
use printed_mlp::argmax::{build_plan, ArgmaxSearchOpts};
use printed_mlp::config::builtin;
use printed_mlp::datasets;
use printed_mlp::model::float_mlp::TrainOpts;
use printed_mlp::model::{FloatMlp, QuantMlp};
use printed_mlp::netlist::mlp::{build_mlp_circuit, ArgmaxMode, MlpCircuitOpts};
use printed_mlp::netlist::Netlist;
use printed_mlp::sim::{eval_nodes, wave};
use printed_mlp::synth::optimize;
use printed_mlp::util::Rng;

fn trained() -> (QuantMlp, datasets::QuantDataset) {
    let cfg = builtin::tiny();
    let (split, qtrain, _) = datasets::load(&cfg.dataset);
    let mut mlp = FloatMlp::init(cfg.topology, 3);
    mlp.train(&split.train, &TrainOpts { epochs: 30, ..Default::default() });
    mlp.train(
        &split.train,
        &TrainOpts { epochs: 15, qat_po2: true, lr: 0.008, ..Default::default() },
    );
    (QuantMlp::from_float(&mlp, &qtrain), qtrain)
}

/// Encode the first `n` rows of a quantized dataset into packed waves.
fn packed_rows(ds: &datasets::QuantDataset, n: usize) -> (Vec<Vec<bool>>, Vec<wave::InputWave>) {
    let encoded: Vec<Vec<bool>> =
        ds.x.iter().take(n).map(|row| wave::encode_features(row, ds.bits)).collect();
    let batches = encoded.chunks(wave::LANES).map(wave::pack_vectors).collect();
    (encoded, batches)
}

/// Wave-classify the `class` bus of a netlist over packed batches.
fn classes(nl: &Netlist, batches: &[wave::InputWave]) -> Vec<usize> {
    wave::classify(nl, batches, "class", 2).into_iter().map(|c| c as usize).collect()
}

#[test]
fn full_approximate_circuit_equals_model_predictions() {
    let (qmlp, qtrain) = trained();
    let map = GenomeMap::new(&qmlp);
    let mut rng = Rng::new(17);
    let genome = map.random_genome(&mut rng, 0.75);
    let masks = map.to_masks(&genome);

    // Approximate argmax plan on the masked model.
    let preacts = qmlp.output_preacts(&qtrain, Some(&masks));
    let plan = build_plan(
        &preacts,
        &qtrain.y,
        qmlp.output_width(),
        &ArgmaxSearchOpts::default(),
    );

    // Full holistic circuit, synthesized.
    let nl = build_mlp_circuit(
        &qmlp,
        &MlpCircuitOpts {
            masks: Some(masks.clone()),
            argmax: ArgmaxMode::Plan(plan.clone()),
        },
    );
    let (opt, stats) = optimize(&nl);
    assert!(stats.cells_out <= stats.cells_in);

    // Wave simulation == model + plan, the whole train set in one sweep.
    let (_, batches) = packed_rows(&qtrain, qtrain.n_samples());
    let got = classes(&opt, &batches);
    for (k, z) in preacts.iter().enumerate() {
        assert_eq!(got[k], plan.predict(z), "sample {k}");
    }
}

#[test]
fn synthesis_never_changes_function() {
    let (qmlp, qtrain) = trained();
    let nl = build_mlp_circuit(&qmlp, &MlpCircuitOpts::default());
    let (opt, _) = optimize(&nl);
    let (_, batches) = packed_rows(&qtrain, qtrain.n_samples());
    // The unoptimized and optimized netlists classify identically.
    assert_eq!(classes(&nl, &batches), classes(&opt, &batches));
}

#[test]
fn wave_engine_is_bit_exact_on_synthesized_mlp() {
    // Lane-by-lane, node-by-node agreement between the wave engine and
    // the scalar reference on a real synthesized circuit — the same
    // property the random-netlist suite checks, pinned on production
    // structure (CSA trees, QRelu, comparator muxes).
    let (qmlp, qtrain) = trained();
    let nl = build_mlp_circuit(&qmlp, &MlpCircuitOpts::default());
    let (opt, _) = optimize(&nl);
    let (encoded, batches) = packed_rows(&qtrain, 150);
    let mut k = 0usize;
    for batch in &batches {
        let words = wave::eval_wave(&opt, batch);
        for lane in 0..batch.n_lanes {
            let scalar = eval_nodes(&opt, &encoded[k]);
            for (i, w) in words.iter().enumerate() {
                assert_eq!(
                    (w >> lane) & 1 == 1,
                    scalar[i],
                    "sample {k} node {i} diverges"
                );
            }
            k += 1;
        }
    }

    // Toggle activity: the wave implementation is the production path;
    // cross-check it against a direct scalar recomputation.
    let act = printed_mlp::sim::toggle_activity(&opt, &encoded);
    let mut toggles = 0u64;
    let mut slots = 0u64;
    let mut prev = eval_nodes(&opt, &encoded[0]);
    for v in &encoded[1..] {
        let cur = eval_nodes(&opt, v);
        for (i, g) in opt.gates.iter().enumerate() {
            if g.is_cell() {
                slots += 1;
                if cur[i] != prev[i] {
                    toggles += 1;
                }
            }
        }
        prev = cur;
    }
    let scalar_act = toggles as f64 / slots as f64;
    assert!(
        (act - scalar_act).abs() < 1e-12,
        "wave activity {act} vs scalar {scalar_act}"
    );
}

#[test]
fn block_wave_engine_is_bit_exact_on_synthesized_mlp() {
    // The 256-lane twin of the test above: lane-by-lane, node-by-node
    // agreement between the `[u64; 4]` block engine and the scalar
    // reference on production structure, including the partial tail
    // block (150 samples = one 128-lane-short batch).
    let (qmlp, qtrain) = trained();
    let nl = build_mlp_circuit(&qmlp, &MlpCircuitOpts::default());
    let (opt, _) = optimize(&nl);
    let encoded: Vec<Vec<bool>> = qtrain
        .x
        .iter()
        .take(150)
        .map(|row| wave::encode_features(row, qtrain.bits))
        .collect();
    let batches: Vec<wave::BlockWave<{ wave::BLOCK_WORDS }>> =
        encoded.chunks(wave::BLOCK_LANES).map(wave::pack_block).collect();
    let mut k = 0usize;
    for batch in &batches {
        let mut values = Vec::new();
        wave::eval_blocks_into(&opt, &batch.blocks, &mut values);
        for lane in 0..batch.n_lanes {
            let (word, bit) = (lane / wave::LANES, lane % wave::LANES);
            let scalar = eval_nodes(&opt, &encoded[k]);
            for (i, b) in values.iter().enumerate() {
                assert_eq!(
                    (b[word] >> bit) & 1 == 1,
                    scalar[i],
                    "sample {k} node {i} diverges"
                );
            }
            k += 1;
        }
    }
    assert_eq!(k, 150);

    // Block classification equals the legacy 64-lane classification on
    // the same stimulus — widths are a pure throughput knob.
    let legacy: Vec<wave::InputWave> =
        encoded.chunks(wave::LANES).map(wave::pack_vectors).collect();
    assert_eq!(
        wave::classify_blocks(&opt, &batches, "class", 2),
        wave::classify(&opt, &legacy, "class", 2),
    );
}

#[test]
fn deeper_masking_monotonically_shrinks_synthesized_area() {
    let (qmlp, _) = trained();
    let map = GenomeMap::new(&qmlp);
    let mut last = usize::MAX;
    for keep in [1.0, 0.7, 0.4, 0.1] {
        let mut rng = Rng::new(23);
        let genome = map.random_genome(&mut rng, keep);
        let masks = map.to_masks(&genome);
        let nl = build_mlp_circuit(
            &qmlp,
            &MlpCircuitOpts { masks: Some(masks), argmax: ArgmaxMode::Exact },
        );
        let (opt, _) = optimize(&nl);
        let cells = opt.cell_count();
        assert!(
            cells <= last,
            "keep={keep}: {cells} cells > previous {last}"
        );
        last = cells;
    }
}

#[test]
fn egfet_reports_scale_with_circuit_size() {
    use printed_mlp::egfet::{analyze_measured, Library};
    let (qmlp, qtrain) = trained();
    let nl_exact = build_mlp_circuit(&qmlp, &MlpCircuitOpts::default());
    let (opt_exact, _) = optimize(&nl_exact);
    let map = GenomeMap::new(&qmlp);
    let mut rng = Rng::new(29);
    let masks = map.to_masks(&map.random_genome(&mut rng, 0.3));
    let nl_small = build_mlp_circuit(
        &qmlp,
        &MlpCircuitOpts { masks: Some(masks), argmax: ArgmaxMode::Exact },
    );
    let (opt_small, _) = optimize(&nl_small);
    let lib = Library::egfet_1v();
    // Measured toggle activity from the same wave-simulated stimulus.
    let (encoded, _) = packed_rows(&qtrain, 100);
    let big = analyze_measured(&opt_exact, &lib, 200.0, &encoded);
    let small = analyze_measured(&opt_small, &lib, 200.0, &encoded);
    assert!(small.area_cm2 < big.area_cm2);
    assert!(small.delay_ms <= big.delay_ms + 1e-9);
    // At matched activity the smaller circuit always burns less power.
    let big_nom = printed_mlp::egfet::analyze(&opt_exact, &lib, 200.0, 0.25);
    let small_nom = printed_mlp::egfet::analyze(&opt_small, &lib, 200.0, 0.25);
    assert!(small_nom.power_mw < big_nom.power_mw);
}
