//! Quickstart: the whole framework on the built-in `tiny` dataset in a
//! few seconds, no artifacts required (native evaluator fallback).
//!
//!     cargo run --release --example quickstart
//!
//! Walks the paper's Fig. 1 flow: train -> po2+QRelu QAT -> genetic
//! accumulation approximation -> approximate Argmax -> gate-level
//! synthesis -> EGFET hardware report -> battery classification.

use printed_mlp::config::builtin;
use printed_mlp::coordinator::{EvalBackend, Pipeline, PipelineOpts};
use printed_mlp::report;

fn main() -> anyhow::Result<()> {
    let mut cfg = builtin::tiny();
    cfg.ga.population = 60;
    cfg.ga.generations = 8;

    let opts = PipelineOpts {
        backend: EvalBackend::Auto,
        max_hw_points: 3,
        verbose: true,
        ..Default::default()
    };
    let result = Pipeline::new(cfg, opts).run()?;

    let baseline = result.baseline_hw.as_ref().unwrap();
    println!("\nexact bespoke baseline [8]: {}", report::hw_cell(baseline));
    println!("QAT-only (po2 + QRelu):     {}", report::hw_cell(&result.qat_hw));
    for d in &result.designs {
        println!(
            "holistic approx (FA {:>4}): {}  acc {:.3}  @0.6V {:.3} mW -> {}",
            d.area_fa,
            report::hw_cell(&d.hw_full),
            d.acc_test_full,
            d.hw_0p6v.power_mw,
            d.power_source.label()
        );
    }
    if let Some(best) = result.best_within_loss(0.05) {
        println!(
            "\nbest <=5% design: {:.1}x area / {:.1}x power vs baseline (backend: {})",
            baseline.area_cm2 / best.hw_full.area_cm2,
            baseline.power_mw / best.hw_full.power_mw,
            result.backend_used,
        );
    }
    Ok(())
}
