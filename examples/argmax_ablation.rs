//! Ablation: what the approximate Argmax contributes on top of the
//! accumulation approximation (the design choice behind paper Table IV).
//!
//!     cargo run --release --example argmax_ablation

use printed_mlp::argmax::{build_plan, ArgmaxPlan, ArgmaxSearchOpts};
use printed_mlp::config::builtin;
use printed_mlp::datasets;
use printed_mlp::egfet::{analyze, Library};
use printed_mlp::netlist::mlp::{build_mlp_circuit, ArgmaxMode, MlpCircuitOpts};
use printed_mlp::synth::optimize;
use printed_mlp::train;

fn main() {
    for name in ["breastcancer", "cardio", "pendigits"] {
        let cfg = builtin::by_name(name).unwrap();
        let (split, qtrain, qtest) = datasets::load(&cfg.dataset);
        let tm = train::train_native(&cfg, &split, &qtrain, &qtest);
        let qmlp = &tm.qmlp;
        let width = qmlp.output_width();

        // Exact argmax.
        let nl = build_mlp_circuit(qmlp, &MlpCircuitOpts::default());
        let (opt, _) = optimize(&nl);
        let hw_exact = analyze(&opt, &Library::egfet_1v(), cfg.hw.clock_ms, 0.25);
        let exact_plan = ArgmaxPlan::exact(qmlp.topo.n_out, width);

        // Approximate argmax (greedy bit subsets + Hungarian pairing).
        let preacts = qmlp.output_preacts(&qtrain, None);
        let plan = build_plan(&preacts, &qtrain.y, width, &ArgmaxSearchOpts::default());
        let nl2 = build_mlp_circuit(
            qmlp,
            &MlpCircuitOpts { masks: None, argmax: ArgmaxMode::Plan(plan.clone()) },
        );
        let (opt2, _) = optimize(&nl2);
        let hw_approx = analyze(&opt2, &Library::egfet_1v(), cfg.hw.clock_ms, 0.25);

        let test_preacts = qmlp.output_preacts(&qtest, None);
        let acc_exact = exact_plan.accuracy(&test_preacts, &qtest.y);
        let acc_approx = plan.accuracy(&test_preacts, &qtest.y);
        let (avg_bits, reduction) = plan.comparator_stats();
        println!(
            "{name:>13}: area {:.3} -> {:.3} cm2 ({:.0}% cut), acc {:.3} -> {:.3}, \
             comparators {}b -> {:.1}b avg ({:.1}x)",
            hw_exact.area_cm2,
            hw_approx.area_cm2,
            100.0 * (1.0 - hw_approx.area_cm2 / hw_exact.area_cm2),
            acc_exact,
            acc_approx,
            width,
            avg_bits,
            reduction
        );
    }
}
