//! End-to-end driver (DESIGN.md: the full-system validation example).
//!
//! Exercises all three layers on the Cardiotocography MLP:
//!   * Layer-2/Layer-1: QAT training runs through the AOT-compiled
//!     `train_step_cardio` program (JAX fwd+bwd+Adam with the Pallas
//!     masked-MAC kernel lowered inside) — the loss curve is logged;
//!   * Layer-3: the genetic accumulation approximation evaluates every
//!     chromosome through `masked_acc_cardio` via PJRT, then the
//!     approximate-Argmax search, gate-level synthesis, and the EGFET
//!     battery analysis run natively.
//!
//! Requires `make artifacts`. Writes `runs/e2e_cardio.json`.
//!
//!     cargo run --release --example e2e_cardio

use printed_mlp::config::builtin;
use printed_mlp::coordinator::{EvalBackend, Pipeline, PipelineOpts};
use printed_mlp::datasets;
use printed_mlp::model::float_mlp::TrainOpts;
use printed_mlp::model::FloatMlp;
use printed_mlp::report;
use printed_mlp::runtime::Runtime;
use printed_mlp::train::PjrtTrainer;

fn main() -> anyhow::Result<()> {
    let t_start = std::time::Instant::now();
    let mut cfg = builtin::cardio();
    cfg.ga.population = 80;
    cfg.ga.generations = 10;

    // --- explicit L2 training-loop demo with loss logging --------------
    let rt = Runtime::new(&Runtime::default_dir())
        .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts` first"))?;
    let (split, _qtrain, _qtest) = datasets::load(&cfg.dataset);
    let mut float = FloatMlp::init(cfg.topology, cfg.train.seed);
    float.train(
        &split.train,
        &TrainOpts { epochs: cfg.train.epochs, lr: cfg.train.lr, ..Default::default() },
    );
    println!("float model: test acc {:.3}", float.accuracy(&split.test, false));
    let trainer = PjrtTrainer::new(&rt, "cardio");
    println!("QAT via AOT train_step (PJRT), loss curve:");
    for round in 0..5 {
        let (qat, loss) = trainer.finetune(&float, &split.train, 4, 0.008, 7 + round)?;
        println!("  epoch {:>2}: loss {:.4}", (round + 1) * 4, loss);
        float = qat;
    }

    // --- the full pipeline (PJRT GA evaluator) --------------------------
    let opts = PipelineOpts {
        backend: EvalBackend::Pjrt,
        max_hw_points: 4,
        verbose: true,
        ..Default::default()
    };
    let result = Pipeline::new(cfg, opts).run()?;

    let baseline = result.baseline_hw.as_ref().unwrap();
    println!("\n=== E2E result (cardio) ===");
    println!("backend: {}", result.backend_used);
    println!("baseline [8]: acc {:.3}, {}", result.baseline_acc_test, report::hw_cell(baseline));
    println!(
        "QAT-only:     acc {:.3}, {}",
        result.trained.acc_q_test,
        report::hw_cell(&result.qat_hw)
    );
    let best = result
        .best_within_loss(0.05)
        .ok_or_else(|| anyhow::anyhow!("no <=5% design found"))?;
    println!(
        "ours (holistic, <=5% loss): acc {:.3}, {} | 0.6V: {:.3} mW -> {}",
        best.acc_test_full,
        report::hw_cell(&best.hw_full),
        best.hw_0p6v.power_mw,
        best.power_source.label()
    );
    println!(
        "headline: {:.0}x area / {:.0}x power vs exact baseline at 0.6V",
        baseline.area_cm2 / best.hw_0p6v.area_cm2,
        baseline.power_mw / best.hw_0p6v.power_mw
    );
    println!("total wall time: {:.1}s", t_start.elapsed().as_secs_f64());

    std::fs::create_dir_all("runs")?;
    std::fs::write(
        "runs/e2e_cardio.json",
        report::result_to_json(&result).to_string_pretty(),
    )?;
    println!("wrote runs/e2e_cardio.json");
    Ok(())
}
