//! Using the public API on your own classification task: define a
//! dataset spec + topology, run the framework, inspect the trade-off.
//!
//!     cargo run --release --example custom_dataset

use printed_mlp::config::{DatasetSpec, GaSpec, HwSpec, RunConfig, Topology, TrainSpec};
use printed_mlp::coordinator::{EvalBackend, Pipeline, PipelineOpts};

fn main() -> anyhow::Result<()> {
    // A hypothetical smart-bandage sensor: 8 analog channels, 4 classes
    // (normal / infection / ischemia / sensor-fault), imbalanced.
    let cfg = RunConfig {
        dataset: DatasetSpec {
            name: "smart-bandage".into(),
            n_features: 8,
            n_classes: 4,
            n_samples: 1200,
            class_weights: vec![0.70, 0.12, 0.10, 0.08],
            separation: 3.5,
            noise: 0.13,
            clusters_per_class: 1,
            nuisance_frac: 0.1,
            seed: 2024,
        },
        topology: Topology::new(8, 4, 4),
        train: TrainSpec { epochs: 60, batch_size: 64, lr: 0.02, seed: 2024 },
        ga: GaSpec {
            population: 60,
            generations: 8,
            mutation_rate: 0.01,
            crossover_rate: 0.9,
            acc_loss_bound: 0.15,
            init_keep_prob: 0.92,
            seed: 2024,
        },
        hw: HwSpec { clock_ms: 200.0, vdd: 1.0 },
    };

    let result = Pipeline::new(
        cfg,
        PipelineOpts { backend: EvalBackend::Native, verbose: true, ..Default::default() },
    )
    .run()?;

    let base = result.baseline_hw.as_ref().unwrap();
    println!("\nsmart-bandage MLP (8,4,4):");
    println!("  exact baseline: {:.2} cm2 / {:.2} mW, acc {:.3}", base.area_cm2, base.power_mw, result.baseline_acc_test);
    for d in &result.designs {
        println!(
            "  approx design:  {:.2} cm2 / {:.2} mW, acc {:.3}, battery: {}",
            d.hw_full.area_cm2,
            d.hw_full.power_mw,
            d.acc_test_full,
            d.power_source.label()
        );
    }
    Ok(())
}
