//! Battery-operation study (the paper's Table V scenario) across all six
//! printed MLPs: best <=5%-loss design per dataset at the 0.6 V corner,
//! with the printed power source able to drive it.
//!
//!     cargo run --release --example battery_report

use printed_mlp::bench::{Scale, Study};
use printed_mlp::coordinator::EvalBackend;

fn main() {
    let mut study = Study::new(Scale::Small, EvalBackend::Auto);
    println!("{}", printed_mlp::bench::table5(&mut study));
    // The headline claim: the 1,450-parameter Arrhythmia MLP must be
    // battery-powered (paper: 20x more parameters than the prior SOTA).
    let r = study.pipeline("arrhythmia");
    if let Some(d) = r.best_within_loss(0.05) {
        println!(
            "Arrhythmia (1450 params): {:.2} mW @0.6V -> {} (paper: Molex 30mW)",
            d.hw_0p6v.power_mw,
            d.power_source.label()
        );
    }
}
