//! Vendored std-only stand-in for the `anyhow` crate.
//!
//! The offline build environment has no crates-io access, so this shim
//! provides the (small) slice of the anyhow 1.x API the workspace uses:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros and
//! the [`Context`] extension trait. Swapping in the real `anyhow = "1"`
//! is a drop-in replacement — nothing here deviates from its semantics
//! for the covered surface.
//!
//! Design notes, mirroring anyhow itself:
//! * `Error` deliberately does **not** implement `std::error::Error`;
//!   that is what allows the blanket `From<E: std::error::Error>` impl to
//!   coexist with `From<Error> for Error` (the core identity impl).
//! * The cause chain is captured eagerly as strings at conversion time —
//!   enough for `{:#}` formatting, which is all the workspace needs.

// The shim is pure safe code; keep it that way by construction.
#![forbid(unsafe_code)]

use std::fmt;

/// `Result<T, anyhow::Error>` with an overridable error type, like anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error: `chain[0]` is the outermost context, deeper
/// causes follow.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap the error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost (most recently attached) message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole cause chain, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait attaching context to fallible results.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 1 {
                bail!("one is not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(-3).unwrap_err()), "negative: -3");
        assert_eq!(format!("{}", f(1).unwrap_err()), "one is not allowed");
        let e = anyhow!("plain {} {}", 1, 2);
        assert_eq!(format!("{e}"), "plain 1 2");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }
}
