//! Regenerates paper Table V: battery operation of the approximate MLPs
//! at the 0.6 V corner (Molex 30mW / Blue Spark 3mW / energy harvester).
//!
//! Backend and GA cost objective come from `PMLP_BACKEND` /
//! `PMLP_OBJECTIVE` (e.g. `PMLP_BACKEND=circuit PMLP_OBJECTIVE=power`
//! selects designs whose GA already minimized measured power).
mod common;
use printed_mlp::bench::Study;

fn main() {
    let mut study =
        Study::new(common::scale(), common::backend()).with_objective(common::objective());
    common::timed("table5", || printed_mlp::bench::table5(&mut study));
}
