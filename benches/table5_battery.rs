//! Regenerates paper Table V: battery operation of the approximate MLPs
//! at the 0.6 V corner (Molex 30mW / Blue Spark 3mW / energy harvester).
mod common;
use printed_mlp::bench::Study;
use printed_mlp::coordinator::EvalBackend;

fn main() {
    let mut study = Study::new(common::scale(), EvalBackend::Auto);
    common::timed("table5", || printed_mlp::bench::table5(&mut study));
}
