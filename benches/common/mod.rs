//! Shared bench scaffolding: scale selection via `PMLP_BENCH_SCALE`
//! (smoke|small|paper; default small), backend/objective selection via
//! `PMLP_BACKEND` (auto|pjrt|native|circuit) and `PMLP_OBJECTIVE`
//! (fa|area|power|delay|area+power|area+power+delay; measured
//! objectives need `PMLP_BACKEND=circuit`, `area+power` drives the
//! joint three-objective front and `area+power+delay` the 4-D one),
//! and a wall-clock banner.

use printed_mlp::bench::Scale;
#[allow(unused_imports)]
use printed_mlp::coordinator::EvalBackend;
#[allow(unused_imports)]
use printed_mlp::egfet::CostObjective;

pub fn scale() -> Scale {
    std::env::var("PMLP_BENCH_SCALE")
        .ok()
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Small)
}

/// GA evaluation backend of the pipeline-driving harnesses
/// (`PMLP_BACKEND`, default auto). A set-but-unrecognized value is a
/// loud error, not a silent fallback — a typo must not regenerate
/// figures with the wrong backend.
#[allow(dead_code)]
pub fn backend() -> EvalBackend {
    match std::env::var("PMLP_BACKEND") {
        Err(_) => EvalBackend::Auto,
        Ok(s) => EvalBackend::parse(&s)
            .unwrap_or_else(|| panic!("bad PMLP_BACKEND '{s}' (auto|pjrt|native|circuit)")),
    }
}

/// GA cost objective of the pipeline-driving harnesses
/// (`PMLP_OBJECTIVE`, default fa). Same loud-error policy as
/// [`backend`]: `PMLP_OBJECTIVE=pwr` must not silently run the FA
/// surrogate. The panic message comes from the detailed parser, which
/// names the offending axis segment and the canonical option list
/// (`egfet::OBJECTIVE_OPTIONS`) — no hand-kept copy here.
#[allow(dead_code)]
pub fn objective() -> CostObjective {
    match std::env::var("PMLP_OBJECTIVE") {
        Err(_) => CostObjective::Fa,
        Ok(s) => CostObjective::parse_detailed(&s)
            .unwrap_or_else(|e| panic!("bad PMLP_OBJECTIVE: {e}")),
    }
}

pub fn timed(name: &str, f: impl FnOnce() -> String) {
    let t0 = std::time::Instant::now();
    let out = f();
    println!("{out}");
    println!(
        "[bench {name}] wall time: {:.2}s (scale: {:?})",
        t0.elapsed().as_secs_f64(),
        scale()
    );
}
