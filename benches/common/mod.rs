//! Shared bench scaffolding: scale selection via `PMLP_BENCH_SCALE`
//! (smoke|small|paper; default small) and a wall-clock banner.

use printed_mlp::bench::Scale;

pub fn scale() -> Scale {
    std::env::var("PMLP_BENCH_SCALE")
        .ok()
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Small)
}

pub fn timed(name: &str, f: impl FnOnce() -> String) {
    let t0 = std::time::Instant::now();
    let out = f();
    println!("{out}");
    println!(
        "[bench {name}] wall time: {:.2}s (scale: {:?})",
        t0.elapsed().as_secs_f64(),
        scale()
    );
}
