//! Regenerates paper Table III: exact bespoke baseline [8] vs QAT-only
//! (po2 + QRelu) accuracy/area/power for all six printed MLPs.
mod common;
use printed_mlp::bench::Study;
use printed_mlp::coordinator::EvalBackend;

fn main() {
    let mut study = Study::new(common::scale(), EvalBackend::Auto);
    common::timed("table3", || printed_mlp::bench::table3(&mut study));
}
