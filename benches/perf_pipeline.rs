//! Perf: end-to-end pipeline wall time per dataset (the paper reports
//! <=3h worst case on a 48-core EPYC at population 1000 x 30
//! generations; our scaled runs must be minutes at most).
mod common;
use printed_mlp::coordinator::{EvalBackend, Pipeline, PipelineOpts};

fn main() {
    common::timed("perf_pipeline", || {
        let mut rows = Vec::new();
        let study = printed_mlp::bench::Study::new(common::scale(), EvalBackend::Auto);
        for name in ["tiny", "cardio", "arrhythmia"] {
            let cfg = study.cfg(name);
            let t0 = std::time::Instant::now();
            let result = Pipeline::new(
                cfg,
                PipelineOpts { backend: EvalBackend::Auto, ..Default::default() },
            )
            .run()
            .expect("pipeline");
            rows.push(vec![
                name.to_string(),
                result.backend_used.to_string(),
                format!("{}", result.cfg.ga.population),
                format!("{}", result.cfg.ga.generations),
                format!("{:.2}s", t0.elapsed().as_secs_f64()),
            ]);
        }
        printed_mlp::report::render_table(
            "end-to-end pipeline wall time",
            &["dataset", "backend", "pop", "gens", "wall"],
            &rows,
        )
    });
}
