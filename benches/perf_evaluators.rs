//! Perf: GA-evaluator throughput (chromosomes/s) — native vs
//! circuit-in-the-loop in both synthesis modes (from-scratch per
//! chromosome vs template + incremental cone-local re-synthesis, on a
//! GA-like mutation chain) vs PJRT when artifacts exist — per dataset;
//! the framework's hot path (EXPERIMENTS.md §Perf). The incremental row
//! reports its speedup over the from-scratch circuit path, and the
//! measured-power objective rows (`--objective power`) track the census
//! + toggle roll-up against from-scratch survivor analysis (target:
//! incremental ≥ 2× full on the mutation chain). The
//! `circuit/incr/area+power` row times the joint three-objective
//! evaluator on the same chain so the const-generic arity
//! generalization's overhead stays visible (target: < 10% vs the single
//! measured objective), and `circuit/incr/area+power+delay` stacks the
//! 4-D timing axis on top — delay read off the incremental arena's
//! arrival table, so the extra axis is bookkeeping (target: < 15% vs
//! the 3-objective row, CI asserts ≥ 0.85×). The `circuit/incr/{64-lane,256-lane,
//! shared-cones}` row triple isolates the wave tentpole: legacy `u64`
//! width (the committed baseline), `[u64; 4]` blocks, and blocks plus
//! the generation-scoped shared-cone memo — CI's smoke leg asserts
//! shared-cones ≥ 2× the 64-lane baseline.
//!
//! The jobs-scaling section measures the population-parallel fan-out of
//! the circuit backend (per-worker synthesis arenas + wave caches) at
//! `--jobs` 1/2/4/8: genomes/sec per width, speedup vs serial, and a
//! bit-identical check across widths. The tentpole target is ≥3× at 8
//! workers over `--jobs 1`.
//!
//! The telemetry section re-runs the `circuit/incr` mutation chain with
//! collection disabled vs enabled (`util::telemetry`) — the row pair
//! that pins instrumentation overhead on the hottest path at < 5%.
//!
//! The verify section runs the same chain with `--verify off` vs
//! `--verify boundaries` (`synth::verify` checkpoints at worker
//! teardown) — the row pair that pins invariant-checking overhead on
//! the hottest path at < 5%, with `off` zero-cost by construction.
//!
//! Every measured rate is also written as a structured record to
//! `BENCH_evaluators.json` (path override: `PMLP_BENCH_JSON`), which CI
//! uploads as an artifact — the perf trajectory's data points.
mod common;
use printed_mlp::bench::{BenchRecord, Scale};

fn main() {
    let mut records: Vec<BenchRecord> = Vec::new();
    common::timed("perf_evaluators", || {
        let (names, n): (Vec<&str>, usize) = match common::scale() {
            Scale::Smoke => (vec!["tiny"], 24),
            _ => (vec!["cardio", "pendigits", "arrhythmia"], 64),
        };
        let n_scaling = match common::scale() {
            Scale::Smoke => 32,
            _ => 96,
        };
        let mut out = String::new();
        for name in &names {
            out.push_str(&printed_mlp::bench::ablation_evaluators_recorded(
                name,
                n,
                &mut records,
            ));
        }
        for name in &names {
            out.push_str(&printed_mlp::bench::jobs_scaling_recorded(
                name,
                n_scaling,
                &[1, 2, 4, 8],
                &mut records,
            ));
        }
        for name in &names {
            out.push_str(&printed_mlp::bench::telemetry_overhead_recorded(
                name,
                n,
                &mut records,
            ));
        }
        for name in &names {
            out.push_str(&printed_mlp::bench::verify_overhead_recorded(
                name,
                n,
                &mut records,
            ));
        }
        out
    });
    let path = std::env::var("PMLP_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_evaluators.json".to_string());
    let json = printed_mlp::bench::records_to_json(common::scale(), &records);
    match std::fs::write(&path, json.to_string_pretty()) {
        Ok(()) => println!("[bench perf_evaluators] wrote {} records to {path}", records.len()),
        Err(e) => eprintln!("[bench perf_evaluators] could not write {path}: {e}"),
    }
}
