//! Perf: GA-evaluator throughput (chromosomes/s) — native vs
//! circuit-in-the-loop in both synthesis modes (from-scratch per
//! chromosome vs template + incremental cone-local re-synthesis, on a
//! GA-like mutation chain) vs PJRT when artifacts exist — per dataset;
//! the framework's hot path (EXPERIMENTS.md §Perf). The incremental row
//! reports its speedup over the from-scratch circuit path.
//!
//! The jobs-scaling section measures the population-parallel fan-out of
//! the circuit backend (per-worker synthesis arenas + wave caches) at
//! `--jobs` 1/2/4/8: genomes/sec per width, speedup vs serial, and a
//! bit-identical check across widths. The tentpole target is ≥3× at 8
//! workers over `--jobs 1`.
mod common;
use printed_mlp::bench::Scale;

fn main() {
    common::timed("perf_evaluators", || {
        let (names, n): (Vec<&str>, usize) = match common::scale() {
            Scale::Smoke => (vec!["tiny"], 24),
            _ => (vec!["cardio", "pendigits", "arrhythmia"], 64),
        };
        let n_scaling = match common::scale() {
            Scale::Smoke => 32,
            _ => 96,
        };
        let mut out = String::new();
        for name in &names {
            out.push_str(&printed_mlp::bench::ablation_evaluators(name, n));
        }
        for name in &names {
            out.push_str(&printed_mlp::bench::jobs_scaling(name, n_scaling, &[1, 2, 4, 8]));
        }
        out
    });
}
