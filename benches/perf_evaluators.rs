//! Perf: GA-evaluator throughput (chromosomes/s) — native vs
//! circuit-in-the-loop (synthesize + wave-classify per chromosome) vs
//! PJRT when artifacts exist — per dataset; the framework's hot path
//! (EXPERIMENTS.md §Perf).
mod common;

fn main() {
    common::timed("perf_evaluators", || {
        let mut out = String::new();
        for name in ["cardio", "pendigits", "arrhythmia"] {
            out.push_str(&printed_mlp::bench::ablation_evaluators(name, 64));
        }
        out
    });
}
