//! Perf: netlist generation + synthesis + analysis throughput on the
//! exact baseline circuits (the Table II sweep's inner loop), plus the
//! simulation section: scalar `eval_nodes` vs the bit-parallel wave
//! engine in vectors/sec on the synthesized netlists (the wave engine's
//! ≥20× target lives here).
mod common;
use printed_mlp::baselines::Int8Mlp;
use printed_mlp::config::builtin;
use printed_mlp::datasets;
use printed_mlp::egfet::{analyze, Library};
use printed_mlp::model::float_mlp::TrainOpts;
use printed_mlp::model::FloatMlp;
use printed_mlp::netlist::mlp::ArgmaxMode;
use printed_mlp::netlist::Netlist;
use printed_mlp::sim::{self, wave};
use printed_mlp::synth::optimize;
use printed_mlp::util::Rng;

/// Simulation throughput of one netlist: (scalar vectors/s, wave
/// vectors/s). Same random stimulus for both engines.
fn sim_rates(nl: &Netlist, n_vectors: usize, seed: u64) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let vectors: Vec<Vec<bool>> = (0..n_vectors)
        .map(|_| (0..nl.n_inputs).map(|_| rng.chance(0.5)).collect())
        .collect();

    let t0 = std::time::Instant::now();
    let mut values = Vec::new();
    for v in &vectors {
        sim::eval_nodes_into(nl, v, &mut values);
    }
    let scalar_rate = n_vectors as f64 / t0.elapsed().as_secs_f64();

    let batches: Vec<wave::InputWave> =
        vectors.chunks(wave::LANES).map(wave::pack_vectors).collect();
    let t0 = std::time::Instant::now();
    let mut words = Vec::new();
    for b in &batches {
        wave::eval_wave_into(nl, &b.words, &mut words);
    }
    let wave_rate = n_vectors as f64 / t0.elapsed().as_secs_f64();
    (scalar_rate, wave_rate)
}

fn main() {
    common::timed("perf_synth", || {
        let mut rows = Vec::new();
        let mut sim_rows = Vec::new();
        for name in ["cardio", "pendigits", "arrhythmia"] {
            let cfg = builtin::by_name(name).unwrap();
            let (split, _, _) = datasets::load(&cfg.dataset);
            let mut mlp = FloatMlp::init(cfg.topology, 1);
            mlp.train(&split.train, &TrainOpts { epochs: 10, ..Default::default() });
            let int8 = Int8Mlp::from_float(&mlp);
            let t0 = std::time::Instant::now();
            let nl = int8.build_circuit(ArgmaxMode::Exact);
            let t_build = t0.elapsed().as_secs_f64();
            let t0 = std::time::Instant::now();
            let (opt, stats) = optimize(&nl);
            let t_synth = t0.elapsed().as_secs_f64();
            let t0 = std::time::Instant::now();
            let hw = analyze(&opt, &Library::egfet_1v(), cfg.hw.clock_ms, 0.25);
            let t_analyze = t0.elapsed().as_secs_f64();
            rows.push(vec![
                name.to_string(),
                format!("{}", stats.cells_in),
                format!("{}", stats.cells_out),
                format!("{t_build:.3}s"),
                format!("{t_synth:.3}s"),
                format!("{t_analyze:.4}s"),
                format!("{:.0}", hw.area_cm2),
            ]);

            let (scalar_rate, wave_rate) = sim_rates(&opt, 4096, 7);
            sim_rows.push(vec![
                name.to_string(),
                format!("{}", opt.cell_count()),
                format!("{scalar_rate:.0}"),
                format!("{wave_rate:.0}"),
                format!("{:.1}x", wave_rate / scalar_rate),
            ]);
        }
        let mut out = printed_mlp::report::render_table(
            "synthesis throughput (exact baseline circuits)",
            &["dataset", "gates in", "cells out", "build", "synth", "analyze", "area cm2"],
            &rows,
        );
        out.push_str(&printed_mlp::report::render_table(
            "simulation throughput (synthesized netlists, 4096 vectors)",
            &["dataset", "cells", "scalar vec/s", "wave vec/s", "speedup"],
            &sim_rows,
        ));
        out
    });
}
