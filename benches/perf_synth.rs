//! Perf: netlist generation + synthesis + analysis throughput on the
//! exact baseline circuits (the Table II sweep's inner loop), the
//! simulation section (scalar `eval_nodes` vs the bit-parallel wave
//! engine at both lane widths — the legacy 64-lane `u64` path and the
//! 256-lane `[u64; 4]` block path — in vectors/sec; the wave engine's
//! ≥20× target), and the
//! incremental re-synthesis section: template cone-patch re-synths/sec
//! per flipped-param count vs from-scratch `optimize` (the ≥5× circuit-
//! backend target rides on this).
//!
//! `PMLP_BENCH_SCALE=smoke` restricts to the tiny dataset with small
//! vector/step counts — the CI regression gate.
mod common;
use printed_mlp::accum::GenomeMap;
use printed_mlp::baselines::Int8Mlp;
use printed_mlp::bench::Scale;
use printed_mlp::config::builtin;
use printed_mlp::datasets;
use printed_mlp::egfet::{analyze, Library};
use printed_mlp::model::float_mlp::TrainOpts;
use printed_mlp::model::{FloatMlp, QuantMlp};
use printed_mlp::netlist::mlp::{build_mlp_template, ArgmaxMode};
use printed_mlp::netlist::Netlist;
use printed_mlp::sim::{self, wave};
use printed_mlp::synth::incremental::IncrementalSynth;
use printed_mlp::synth::optimize;
use printed_mlp::util::Rng;

/// Simulation throughput of one netlist: (scalar vectors/s, 64-lane
/// wave vectors/s, 256-lane block vectors/s). Same random stimulus for
/// all three engines; the 64-lane row exercises the legacy `u64` entry
/// point (which must keep compiling and performing as the thin `W = 1`
/// wrapper it now is), the block row the production `[u64; 4]` width.
fn sim_rates(nl: &Netlist, n_vectors: usize, seed: u64) -> (f64, f64, f64) {
    let mut rng = Rng::new(seed);
    let vectors: Vec<Vec<bool>> = (0..n_vectors)
        .map(|_| (0..nl.n_inputs).map(|_| rng.chance(0.5)).collect())
        .collect();

    let t0 = std::time::Instant::now();
    let mut values = Vec::new();
    for v in &vectors {
        sim::eval_nodes_into(nl, v, &mut values);
    }
    let scalar_rate = n_vectors as f64 / t0.elapsed().as_secs_f64();

    let batches: Vec<wave::InputWave> =
        vectors.chunks(wave::LANES).map(wave::pack_vectors).collect();
    let t0 = std::time::Instant::now();
    let mut words = Vec::new();
    for b in &batches {
        wave::eval_wave_into(nl, &b.words, &mut words);
    }
    let wave_rate = n_vectors as f64 / t0.elapsed().as_secs_f64();

    let blocks: Vec<_> = vectors.chunks(wave::BLOCK_LANES).map(wave::pack_block).collect();
    let t0 = std::time::Instant::now();
    let mut block_values = Vec::new();
    for b in &blocks {
        wave::eval_blocks_into(nl, &b.blocks, &mut block_values);
    }
    let block_rate = n_vectors as f64 / t0.elapsed().as_secs_f64();
    (scalar_rate, wave_rate, block_rate)
}

fn main() {
    common::timed("perf_synth", || {
        let scale = common::scale();
        let (names, n_vectors, n_full, resynth_steps): (Vec<&str>, usize, usize, usize) =
            match scale {
                Scale::Smoke => (vec!["tiny"], 512, 3, 24),
                _ => (vec!["cardio", "pendigits", "arrhythmia"], 4096, 8, 64),
            };

        let mut rows = Vec::new();
        let mut sim_rows = Vec::new();
        let mut inc_rows = Vec::new();
        for name in &names {
            let cfg = builtin::by_name(name).unwrap();
            let (split, qtrain, _) = datasets::load(&cfg.dataset);
            let mut mlp = FloatMlp::init(cfg.topology, 1);
            mlp.train(&split.train, &TrainOpts { epochs: 10, ..Default::default() });
            let int8 = Int8Mlp::from_float(&mlp);
            let t0 = std::time::Instant::now();
            let nl = int8.build_circuit(ArgmaxMode::Exact);
            let t_build = t0.elapsed().as_secs_f64();
            let t0 = std::time::Instant::now();
            let (opt, stats) = optimize(&nl);
            let t_synth = t0.elapsed().as_secs_f64();
            let t0 = std::time::Instant::now();
            let hw = analyze(&opt, &Library::egfet_1v(), cfg.hw.clock_ms, 0.25);
            let t_analyze = t0.elapsed().as_secs_f64();
            rows.push(vec![
                name.to_string(),
                format!("{}", stats.cells_in),
                format!("{}", stats.cells_out),
                format!("{t_build:.3}s"),
                format!("{t_synth:.3}s"),
                format!("{t_analyze:.4}s"),
                format!("{:.0}", hw.area_cm2),
            ]);

            let (scalar_rate, wave_rate, block_rate) = sim_rates(&opt, n_vectors, 7);
            sim_rows.push(vec![
                name.to_string(),
                format!("{}", opt.cell_count()),
                format!("{scalar_rate:.0}"),
                format!("{wave_rate:.0}"),
                format!("{block_rate:.0}"),
                format!("{:.1}x", wave_rate / scalar_rate),
                format!("{:.1}x", block_rate / wave_rate),
            ]);

            // ---- incremental vs from-scratch re-synthesis --------------
            // Template of the quantized MLP; from-scratch baseline is
            // `optimize(instantiate)` per genome, incremental is a
            // `set_params` chain flipping k mask bits per step.
            let qmlp = QuantMlp::from_float(&mlp, &qtrain);
            let map = GenomeMap::new(&qmlp);
            let tpl = build_mlp_template(&qmlp, &ArgmaxMode::Exact);
            let mut rng = Rng::new(11);
            let base = map.random_genome(&mut rng, 0.8);
            let t0 = std::time::Instant::now();
            let mut g = base.clone();
            for _ in 0..n_full {
                g.flip(rng.below(map.len()));
                let _ = optimize(&tpl.instantiate(&g));
            }
            let full_rate = n_full as f64 / t0.elapsed().as_secs_f64();
            let mut row = vec![
                name.to_string(),
                format!("{}", map.len()),
                format!("{full_rate:.1}"),
            ];
            for k in [1usize, 4, 16] {
                let mut inc = IncrementalSynth::new(tpl.clone());
                let mut g = base.clone();
                inc.set_params(&g); // prime: the one full pass
                let t0 = std::time::Instant::now();
                for _ in 0..resynth_steps {
                    for _ in 0..k {
                        g.flip(rng.below(map.len()));
                    }
                    inc.set_params(&g);
                }
                let rate = resynth_steps as f64 / t0.elapsed().as_secs_f64();
                row.push(format!("{rate:.0} ({:.0}x)", rate / full_rate));
            }
            inc_rows.push(row);
        }
        let mut out = printed_mlp::report::render_table(
            "synthesis throughput (exact baseline circuits)",
            &["dataset", "gates in", "cells out", "build", "synth", "analyze", "area cm2"],
            &rows,
        );
        out.push_str(&printed_mlp::report::render_table(
            &format!("simulation throughput (synthesized netlists, {n_vectors} vectors)"),
            &[
                "dataset",
                "cells",
                "scalar vec/s",
                "64-lane vec/s",
                "256-lane vec/s",
                "64L/scalar",
                "256L/64L",
            ],
            &sim_rows,
        ));
        out.push_str(&printed_mlp::report::render_table(
            "incremental re-synthesis (re-synths/s at k flipped params, vs from-scratch)",
            &["dataset", "genome bits", "full synth/s", "incr @k=1", "@k=4", "@k=16"],
            &inc_rows,
        ));
        out
    });
}
