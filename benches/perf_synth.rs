//! Perf: netlist generation + synthesis + analysis throughput on the
//! exact baseline circuits (the Table II sweep's inner loop).
mod common;
use printed_mlp::baselines::Int8Mlp;
use printed_mlp::config::builtin;
use printed_mlp::datasets;
use printed_mlp::egfet::{analyze, Library};
use printed_mlp::model::float_mlp::TrainOpts;
use printed_mlp::model::FloatMlp;
use printed_mlp::netlist::mlp::ArgmaxMode;
use printed_mlp::synth::optimize;

fn main() {
    common::timed("perf_synth", || {
        let mut rows = Vec::new();
        for name in ["cardio", "pendigits", "arrhythmia"] {
            let cfg = builtin::by_name(name).unwrap();
            let (split, _, _) = datasets::load(&cfg.dataset);
            let mut mlp = FloatMlp::init(cfg.topology, 1);
            mlp.train(&split.train, &TrainOpts { epochs: 10, ..Default::default() });
            let int8 = Int8Mlp::from_float(&mlp);
            let t0 = std::time::Instant::now();
            let nl = int8.build_circuit(ArgmaxMode::Exact);
            let t_build = t0.elapsed().as_secs_f64();
            let t0 = std::time::Instant::now();
            let (opt, stats) = optimize(&nl);
            let t_synth = t0.elapsed().as_secs_f64();
            let t0 = std::time::Instant::now();
            let hw = analyze(&opt, &Library::egfet_1v(), cfg.hw.clock_ms, 0.25);
            let t_analyze = t0.elapsed().as_secs_f64();
            rows.push(vec![
                name.to_string(),
                format!("{}", stats.cells_in),
                format!("{}", stats.cells_out),
                format!("{t_build:.3}s"),
                format!("{t_synth:.3}s"),
                format!("{t_analyze:.4}s"),
                format!("{:.0}", hw.area_cm2),
            ]);
        }
        printed_mlp::report::render_table(
            "synthesis throughput (exact baseline circuits)",
            &["dataset", "gates in", "cells out", "build", "synth", "analyze", "area cm2"],
            &rows,
        )
    });
}
