//! Regenerates paper Fig. 5: ours vs [7] (truncation), [10] (pruning +
//! VOS), [14] (stochastic computing), normalized to the exact baseline.
mod common;
use printed_mlp::bench::Study;
use printed_mlp::coordinator::EvalBackend;

fn main() {
    let mut study = Study::new(common::scale(), EvalBackend::Auto);
    common::timed("fig5", || printed_mlp::bench::fig5(&mut study));
}
