//! Regenerates paper Fig. 4: accuracy-vs-area Pareto fronts of the
//! genetic accumulation approximation, normalized to the QAT-only design.
//!
//! Backend and GA cost objective come from `PMLP_BACKEND` /
//! `PMLP_OBJECTIVE` (e.g. `PMLP_BACKEND=circuit PMLP_OBJECTIVE=power`
//! reruns the fronts with the measured-hardware objective in the loop).
mod common;
use printed_mlp::bench::Study;

fn main() {
    let mut study =
        Study::new(common::scale(), common::backend()).with_objective(common::objective());
    common::timed("fig4", || printed_mlp::bench::fig4(&mut study));
}
