//! Regenerates paper Fig. 4: accuracy-vs-area Pareto fronts of the
//! genetic accumulation approximation, normalized to the QAT-only design.
mod common;
use printed_mlp::bench::Study;
use printed_mlp::coordinator::EvalBackend;

fn main() {
    let mut study = Study::new(common::scale(), EvalBackend::Auto);
    common::timed("fig4", || printed_mlp::bench::fig4(&mut study));
}
