//! Regenerates paper Table IV: accuracy/area/comparator-size impact of
//! the approximate Argmax on the accumulation-approximated designs.
mod common;
use printed_mlp::bench::Study;
use printed_mlp::coordinator::EvalBackend;

fn main() {
    let mut study = Study::new(common::scale(), EvalBackend::Auto);
    common::timed("table4", || printed_mlp::bench::table4(&mut study));
}
