//! Regenerates paper Table II: Spearman rank correlation of the FA-count
//! area surrogate vs synthesized area (paper: >=0.96 per dataset).
//! `PMLP_BENCH_SCALE=paper` runs the paper's 1000 chromosomes/dataset.
mod common;

fn main() {
    let scale = common::scale();
    common::timed("table2", || printed_mlp::bench::table2(scale));
}
